"""Refactor-equivalence guard for the vectorized/incremental water-fill.

The engine's fast fill (``fill="fast"``: numpy progressive fill + dirty
endpoint-component tracking) must be **bit-identical** — not approximately
equal — to the exported reference ``fair_share``, because the static cost
analyzer's conformance anchor (``analyze_program == healthy_completion``,
bit-exact for lockstep-uniform entries) prices rounds through the same
kernel.  Three layers of pinning:

* property suite: ``fair_share_fast`` vs ``fair_share`` on randomized flow
  sets (weighted streams, zero-capacity endpoints, shared endpoints,
  single-flow degenerates) — exact rate-dict equality;
* corpus-wide timelines: every builder schedule/program runs on both fill
  backends and every report field (completion, per-segment finish,
  link_bytes, retransmits, events, payloads) must match exactly;
* scenario timelines: multi-stream contention, hard failures + flaps, and
  a mid-collective replan through a duck-typed control plane.
"""

import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.analysis.corpus import builder_corpus
from repro.analysis.cost import as_program
from repro.core.event_sim import (
    EventSimulator,
    RecoveryDecision,
    Stream,
    fair_share,
    fair_share_fast,
    simulate_program,
)
from repro.core.failures import link_flap, nic_down_at, slow_nic
from repro.core.schedule import ring_program, tree_program


@dataclasses.dataclass
class _F:
    tid: int
    src: int
    dst: int
    weight: float = 1.0


def _data(n, size, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.normal(size=size) for _ in range(n)]


def _assert_identical(a, b):
    """Every observable of two EventSimReports must match bit-exactly."""
    assert a.completion_time == b.completion_time
    assert a.segment_finish == b.segment_finish
    assert a.link_bytes == b.link_bytes
    assert a.rank_tx_bytes == b.rank_tx_bytes
    assert a.rank_rx_bytes == b.rank_rx_bytes
    assert a.retransmitted_bytes == b.retransmitted_bytes
    assert a.failovers == b.failovers
    assert a.replans == b.replans
    assert a.cancelled_transfers == b.cancelled_transfers
    assert a.transfers == b.transfers
    assert a.events == b.events
    assert (a.rank_data is None) == (b.rank_data is None)
    if a.rank_data is not None:
        for x, y in zip(a.rank_data, b.rank_data):
            assert np.array_equal(x, y)
    assert set(a.streams) == set(b.streams)
    for name, sa in a.streams.items():
        sb = b.streams[name]
        assert sa.completion_time == sb.completion_time
        assert sa.moved_bytes == sb.moved_bytes
        assert sa.retransmitted_bytes == sb.retransmitted_bytes
        assert sa.failovers == sb.failovers
        assert sa.replans == sb.replans
        assert sa.cancelled_transfers == sb.cancelled_transfers


# ---------------------------------------------------------------------------
# fill-function property suite: bit-identical rate dicts
# ---------------------------------------------------------------------------

def test_fair_share_fast_degenerate_cases():
    cap = lambda r: 50e9  # noqa: E731
    assert fair_share_fast([], cap) == {}
    single = [_F(7, 0, 1)]
    assert fair_share_fast(single, cap) == fair_share(single, cap)
    # zero-capacity endpoint: the starved flow rates 0.0, the rest fill
    caps = [0.0, 50e9, 50e9, 25e9]
    by_rank = caps.__getitem__
    flows = [_F(0, 0, 1), _F(1, 1, 2, 2.5), _F(2, 3, 2, 0.5), _F(3, 2, 3)]
    assert fair_share_fast(flows, by_rank) == fair_share(flows, by_rank)
    # many flows sharing both endpoints (the general multi-round loop)
    flows = [_F(i, 0, 1 + (i % 3), 0.5 + 0.3 * i) for i in range(9)]
    assert fair_share_fast(flows, cap) == fair_share(flows, cap)


@settings(max_examples=80, deadline=None)
@given(data=st.data())
def test_fair_share_fast_bit_identical_random(data):
    """Randomized flow sets — weighted, shared-endpoint, zero-capacity —
    must produce exactly the reference's rate dict."""
    n = data.draw(st.integers(2, 12), "n")
    m = data.draw(st.integers(1, 32), "flows")
    flows = []
    for i in range(m):
        src = data.draw(st.integers(0, n - 1), "src")
        dst = data.draw(st.integers(0, n - 2), "dst")
        if dst >= src:
            dst += 1
        w = data.draw(st.sampled_from(
            [1.0, 1.0, 1.0, 0.5, 2.5, 0.125, 3.7]), "weight")
        flows.append(_F(i, src, dst, w))
    caps = [data.draw(st.sampled_from(
        [0.0, 1e6, 12.5e9, 25e9, 50e9, 97.3e9]), "cap") for _ in range(n)]
    ref = fair_share(flows, caps.__getitem__)
    fast = fair_share_fast(flows, caps.__getitem__)
    assert fast == ref


# ---------------------------------------------------------------------------
# corpus-wide engine equivalence: fast vs reference timelines
# ---------------------------------------------------------------------------

def test_fast_fill_bit_identical_across_builder_corpus():
    """Every builder schedule/program: the fast path's full report equals
    the reference fill's, field for field."""
    checked = 0
    for label, obj in builder_corpus(seed=0, max_n=8):
        prog = as_program(obj)
        caps = [50e9] * prog.n
        fast = simulate_program(prog, 8e6, capacities=caps, g=8)
        ref = simulate_program(prog, 8e6, capacities=caps, g=8,
                               fill="reference")
        try:
            _assert_identical(fast, ref)
        except AssertionError as e:  # pragma: no cover - diagnostic
            raise AssertionError(f"fast != reference for {label}: {e}") from e
        checked += 1
    assert checked > 150


@pytest.mark.parametrize("caps", [
    [50e9, 37e9, 50e9, 12e9, 50e9, 44e9],
    [25e9, 25e9, 5e9, 25e9, 25e9, 25e9],
])
def test_fast_fill_identical_heterogeneous_capacities(caps):
    n = len(caps)
    for prog in (ring_program(list(range(n)), n),
                 tree_program(list(range(n)), n)):
        fast = simulate_program(prog, 64e6, capacities=caps, g=8)
        ref = simulate_program(prog, 64e6, capacities=caps, g=8,
                               fill="reference")
        _assert_identical(fast, ref)


# ---------------------------------------------------------------------------
# scenario equivalence: failures, multi-stream contention, mid-run replan
# ---------------------------------------------------------------------------

def test_fast_fill_identical_under_failures():
    n = 8
    prog = ring_program(list(range(n)), n)
    fails = [nic_down_at(2, 0, 2e-4), link_flap(5, 1, 5e-4, 3e-4),
             slow_nic(0, 2, 1e-4, 0.6)]
    kw = dict(capacities=[50e9] * n, g=8, failures=fails,
              rank_data=_data(n, 64))
    fast = simulate_program(prog, 500e6, **kw)
    ref = simulate_program(prog, 500e6, fill="reference", **kw)
    assert fast.retransmitted_bytes > 0      # the failure actually bit
    _assert_identical(fast, ref)


def test_fast_fill_identical_multi_stream():
    n = 8
    streams = [
        Stream("tp", ring_program(list(range(n)), n), 200e6, priority=2.5),
        Stream("dp", tree_program(list(range(n)), n), 150e6, priority=1.0,
               start_time=1e-4),
        Stream("pp", ring_program(list(range(4)), n), 60e6, priority=0.5,
               start_time=2e-4),
    ]
    fails = [nic_down_at(3, 0, 3e-4), link_flap(6, 1, 4e-4, 2e-4)]

    def run(fill):
        return EventSimulator(streams=streams, capacities=[50e9] * n, g=8,
                              failures=fails, fill=fill).run()

    _assert_identical(run("fast"), run("reference"))


class _SwapController:
    """Minimal duck-typed control plane: on the first failure, derive a
    repair delay, rescale the failed rank's residual capacity, and swap in
    a replacement program mid-collective."""

    def __init__(self, prog):
        self.prog = prog
        self.fired = False

    def on_failure(self, sim, now, f):
        if self.fired:
            return RecoveryDecision(repair_latency=1.2e-3)
        self.fired = True
        return RecoveryDecision(
            repair_latency=1.2e-3, capacity_scale={f.node: 0.8},
            replan=self.prog, replan_delay=8e-4)

    def on_recover(self, sim, now, f):
        return None


def test_fast_fill_identical_mid_replan():
    n = 8
    prog = ring_program(list(range(n)), n)
    swap = tree_program(list(range(n)), n)
    fails = [nic_down_at(2, 0, 2.5e-4)]

    def run(fill):
        return simulate_program(
            prog, 300e6, capacities=[50e9] * n, g=8, failures=fails,
            rank_data=_data(n, 48), controller=_SwapController(swap),
            fill=fill)

    fast, ref = run("fast"), run("reference")
    assert fast.replans == 1                 # the swap actually happened
    assert fast.cancelled_transfers > 0
    _assert_identical(fast, ref)


def test_incremental_path_exercised_and_identical():
    """Two endpoint-disjoint streams + a failure on one of them: the fast
    path must take the component-scoped refill (not just full recomputes)
    and still match the reference timeline exactly."""
    n = 8
    streams = [
        Stream("a", ring_program([0, 1, 2, 3], n), 120e6),
        Stream("b", ring_program([4, 5, 6, 7], n), 90e6, priority=2.0,
               start_time=1e-4),
    ]
    fails = [link_flap(1, 0, 2e-4, 3e-4)]
    sf = EventSimulator(streams=streams, capacities=[50e9] * n, g=8,
                        failures=fails, fill="fast")
    fast = sf.run()
    ref = EventSimulator(streams=streams, capacities=[50e9] * n, g=8,
                         failures=fails, fill="reference").run()
    _assert_identical(fast, ref)
    assert sf.fill_partial_recomputes > 0


def test_fast_fill_deterministic_run_to_run():
    n = 6
    prog = ring_program(list(range(n)), n)
    fails = [nic_down_at(1, 0, 2e-4)]
    kw = dict(capacities=[50e9] * n, g=8, failures=fails)
    a = simulate_program(prog, 200e6, **kw)
    b = simulate_program(prog, 200e6, **kw)
    _assert_identical(a, b)


def test_fill_argument_validated():
    prog = ring_program([0, 1], 2)
    with pytest.raises(Exception, match="fill"):
        simulate_program(prog, 1e6, capacities=[50e9] * 2, g=8,
                         fill="bogus")
