"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode
+ hypothesis property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.chunk_combine import chunk_combine_pallas
from repro.kernels.lru_scan import lru_scan_pallas


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tq,tk", [(64, 64), (128, 256), (96, 160)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_shapes_dtypes(tq, tk, dtype):
    key = jax.random.PRNGKey(0)
    B, KVH, G, D = 2, 2, 2, 32
    q = jax.random.normal(key, (B, tq, KVH, G, D), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, tk, KVH, D), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, tk, KVH, D), dtype)
    out = ops.flash_attention(q, k, v, q_block=32, kv_block=64)
    want = ref.reference_attention(q, k, v)
    atol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=atol)


@pytest.mark.parametrize("kw", [
    dict(window=16), dict(prefix_len=8), dict(logit_cap=20.0),
    dict(causal=False), dict(window=32, logit_cap=50.0),
])
def test_flash_mask_variants(kw):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 128, 2, 1, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 128, 2, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 128, 2, 16))
    out = ops.flash_attention(q, k, v, q_block=32, kv_block=32, **kw)
    want = ref.reference_attention(q, k, v, **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_flash_vs_model_blockwise():
    """The model's blockwise attention and the kernel agree (same mask
    semantics by construction)."""
    from repro.models.layers import blockwise_attention
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (1, 64, 2, 2, 16))
    k = jax.random.normal(jax.random.PRNGKey(4), (1, 64, 2, 16))
    v = jax.random.normal(jax.random.PRNGKey(5), (1, 64, 2, 16))
    a = blockwise_attention(q, k, v, causal=True, window=24)
    b = ops.flash_attention(q, k, v, causal=True, window=24,
                            q_block=16, kv_block=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


# ---------------------------------------------------------------------------
# chunk combine
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(c=st.integers(1, 12), m=st.integers(1, 700), seed=st.integers(0, 99))
def test_chunk_combine_property(c, m, seed):
    rng = np.random.default_rng(seed)
    local = jnp.asarray(rng.normal(size=(c, m)).astype(np.float32))
    recv = jnp.asarray(rng.normal(size=(c, m)).astype(np.float32))
    seg = jnp.asarray(rng.integers(0, 2, c).astype(np.int32))
    acc = jnp.asarray(rng.integers(0, 2, c).astype(np.int32))
    out = ops.chunk_combine(local, recv, seg, acc, tile=128)
    want = ref.reference_chunk_combine(local, recv, seg.astype(bool),
                                       acc.astype(bool))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-6)


# ---------------------------------------------------------------------------
# LRU scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,t,w", [(1, 64, 32), (2, 128, 64), (3, 100, 50)])
def test_lru_scan_shapes(b, t, w):
    key = jax.random.PRNGKey(0)
    a = jax.random.uniform(key, (b, t, w), minval=0.3, maxval=0.999)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, t, w))
    out = ops.lru_scan(a, x, time_tile=32, width_tile=32, batch_tile=2)
    want = ref.reference_lru_scan(a, x, jnp.zeros((b, w)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(t=st.integers(2, 200), seed=st.integers(0, 20))
def test_lru_scan_property(t, seed):
    key = jax.random.PRNGKey(seed)
    a = jax.random.uniform(key, (1, t, 16), minval=0.1, maxval=0.99)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, t, 16))
    out = ops.lru_scan(a, x, time_tile=64, width_tile=16, batch_tile=1)
    want = ref.reference_lru_scan(a, x, jnp.zeros((1, 16)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_lru_matches_model_scan():
    """Kernel oracle == the model's associative scan used by RG-LRU."""
    from repro.models.rglru import lru_scan_ref as model_scan
    key = jax.random.PRNGKey(0)
    a = jax.random.uniform(key, (2, 37, 8), minval=0.2, maxval=0.98)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 37, 8))
    h0 = jnp.zeros((2, 8))
    np.testing.assert_allclose(
        np.asarray(ref.reference_lru_scan(a, x, h0)),
        np.asarray(model_scan(a, x, h0)), atol=1e-5)


# ---------------------------------------------------------------------------
# WKV scan (RWKV-6)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bh,t,kd,vd", [(2, 32, 8, 8), (1, 100, 16, 16)])
def test_wkv_scan_shapes(bh, t, kd, vd):
    key = jax.random.PRNGKey(0)
    r = jax.random.normal(key, (bh, t, kd)) * 0.3
    k = jax.random.normal(jax.random.PRNGKey(1), (bh, t, kd)) * 0.3
    v = jax.random.normal(jax.random.PRNGKey(2), (bh, t, vd)) * 0.3
    w = jax.random.uniform(jax.random.PRNGKey(3), (bh, t, kd),
                           minval=0.5, maxval=0.99)
    u = jax.random.normal(jax.random.PRNGKey(4), (bh, kd)) * 0.1
    out = ops.wkv_scan(r, k, v, w, u, time_tile=16)
    want = ref.reference_wkv(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_wkv_matches_model_scan():
    """Kernel oracle == the RWKV-6 model's multi-head wkv scan."""
    from repro.models.rwkv6 import wkv_scan_ref
    key = jax.random.PRNGKey(0)
    B, T, H, K = 2, 24, 3, 8
    r = jax.random.normal(key, (B, T, H, K)) * 0.3
    k = jax.random.normal(jax.random.PRNGKey(1), (B, T, H, K)) * 0.3
    v = jax.random.normal(jax.random.PRNGKey(2), (B, T, H, K)) * 0.3
    w = jax.random.uniform(jax.random.PRNGKey(3), (B, T, H, K),
                           minval=0.5, maxval=0.99)
    u = jax.random.normal(jax.random.PRNGKey(4), (H, K)) * 0.1
    model_out, _ = wkv_scan_ref(r, k, v, w, u,
                                jnp.zeros((B, H, K, K), jnp.float32))
    # flatten (B,H) and broadcast u to per-row form for the kernel oracle
    rr = r.transpose(0, 2, 1, 3).reshape(B * H, T, K)
    kk = k.transpose(0, 2, 1, 3).reshape(B * H, T, K)
    vv = v.transpose(0, 2, 1, 3).reshape(B * H, T, K)
    ww = w.transpose(0, 2, 1, 3).reshape(B * H, T, K)
    uu = jnp.tile(u, (B, 1))
    kern = ops.wkv_scan(rr, kk, vv, ww, uu, time_tile=8)
    want = model_out.transpose(0, 2, 1, 3).reshape(B * H, T, K)
    np.testing.assert_allclose(np.asarray(kern), np.asarray(want),
                               atol=1e-5, rtol=1e-5)
